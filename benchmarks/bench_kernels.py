"""Per-iteration microbench of the Krylov iteration bodies (PR 4, PR 10).

Times N back-to-back iterations of each formulation on random state, at
32³ and 64³ (f64, 27-pt — the paper's setting), and writes
``BENCH_kernels.json`` at the repo root (the measured-perf trajectory the
CI bench-smoke step uploads).  Three families:

  * ``*_classic_kernels`` — the classic iteration as separately dispatched
    kernels (SpMV, dots, axpys) driven by a host loop: the fork-join
    kernel-switch baseline, every switch a dispatch + HBM round trip (the
    paper's §3.3 task-merging target).  CG (6 dispatches/iter) and
    BiCGStab (11 dispatches/iter).
  * ``*_jit`` — N iterations of the classic / merged / pipelined body
    inside ONE compiled ``fori_loop`` (the regime the actual solvers run
    in; merged and pipelined carry their extra recurrences, single
    stacked reduction).
  * ``fused_*_iteration`` — the merged/pipelined iteration via the fused
    kernels: 2 VMEM-resident passes per iteration on TPU; their
    single-pass jnp references composed into the same loop elsewhere.
    Every row records the implementation that ACTUALLY ran in its
    ``impl`` field (``pallas`` / ``pallas-interpret`` / ``jnp-ref`` /
    ``jit`` / ``fork-join`` / ``xla-fallback(...)``) — ``--check`` fails
    if a gated comparison ran the interpret-mode emulator, which is not a
    measurement.

``cg_classic_kernels_auto`` is the PR-10 autotuner row: what the facade
actually executes for a classic solve with ``pallas="auto"`` at this
grid.  Below the Pallas/XLA crossover the autotuner falls back to the
jitted XLA loop (the 16³ case where the kernel path used to be 3.5×
slower), so the row reuses ``cg_classic_jit``'s measured time and is
gated at ``<= cg_classic_jit × 1.1``.

Per-iteration time = min over repeats of (N-iteration wall clock)/N — the
min (not median) because this measures the kernels, not container noise.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_kernels            # full
    PYTHONPATH=src python -m benchmarks.bench_kernels --smoke    # CI gate
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax import lax

from benchmarks.common import csv, trajectory_append, trajectory_row
from repro.core.operators import STENCILS
from repro.core.problems import enable_f64
from repro.core.solvers import _cg_merged_scalars
from repro.kernels import autotune, ops, ref

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GRIDS = ((32, 32, 32), (64, 64, 64))
SMOKE_GRIDS = ((16, 16, 16),)

#: the fused bodies that get a trajectory-history row per grid, and the
#: fork-join baseline each is gated against (ratio >= GATE_MIN)
FUSED_GATES = {
    "fused_iteration": "cg_classic_kernels",
    "fused_pipe_iteration": "cg_classic_kernels",
    "fused_bicgstab_iteration": "bicgstab_classic_kernels",
}
GATE_MIN = 1.0          # fused must be >= the fork-join baseline
AUTO_GATE_MAX = 1.1     # auto row must be <= cg_classic_jit × this


def _state(shape, dtype, n=6):
    ks = jax.random.split(jax.random.PRNGKey(0), n)
    return tuple(jax.random.normal(k, shape, dtype) for k in ks)


def _impl_label(use_pallas: bool) -> str:
    """What actually executes inside the fused rows."""
    if not use_pallas:
        return "jnp-ref"
    return "pallas" if jax.default_backend() == "tpu" else "pallas-interpret"


def _runners(stencil, n_iters: int, state, use_pallas: bool):
    """name -> (zero-arg callable running ``n_iters`` iterations, impl)."""
    mvp = stencil.matvec_padded
    x, r, p, s, w, z, t, v, rhat = state
    one = jnp.asarray(1.0, x.dtype)
    inf = jnp.asarray(jnp.inf, x.dtype)
    rr = jnp.vdot(r, r)
    delta = jnp.vdot(w, r)
    fused_impl = _impl_label(use_pallas)

    # -- classic CG, six separate kernel dispatches per iteration -------------
    k_spmv = jax.jit(lambda u: mvp(jnp.pad(u, 1)))
    k_dot = jax.jit(jnp.vdot)
    k_axpy = jax.jit(lambda a, u, q: u + a * q)

    def cg_classic_kernels():
        xc, rc, pc, rrc = x, r, p, rr
        for _ in range(n_iters):
            Ap = k_spmv(pc)
            pAp = k_dot(pc, Ap)
            alpha = rrc / pAp
            xc = k_axpy(alpha, xc, pc)
            rc = k_axpy(-alpha, rc, Ap)
            rr_new = k_dot(rc, rc)
            beta = rr_new / rrc
            pc = k_axpy(beta, rc, pc)
            rrc = rr_new
        return jax.block_until_ready((xc, rc, pc, rrc))

    # -- classic BiCGStab, eleven separate kernel dispatches per iteration ----
    def bicgstab_classic_kernels():
        xc, rc, pc, vc = x, r, p, v
        alpha = omega = rho = jnp.asarray(1.0, x.dtype)
        for _ in range(n_iters):
            rho_new = k_dot(rhat, rc)
            beta = (rho_new / rho) * (alpha / omega)
            pc = k_axpy(beta, rc, k_axpy(-omega, pc, vc))
            vc = k_spmv(pc)
            alpha = rho_new / k_dot(rhat, vc)
            sc = k_axpy(-alpha, rc, vc)
            tc = k_spmv(sc)
            omega = k_dot(tc, sc) / k_dot(tc, tc)
            xc = k_axpy(omega, k_axpy(alpha, xc, pc), sc)
            rc = k_axpy(-omega, sc, tc)
            rho = rho_new
        return jax.block_until_ready((xc, rc, pc, vc))

    # -- whole-loop compiled variants -----------------------------------------
    def classic_body(_, c):
        xc, rc, pc, rrc = c
        Ap = mvp(jnp.pad(pc, 1))
        alpha = rrc / jnp.vdot(pc, Ap)
        xc = xc + alpha * pc
        rc = rc - alpha * Ap
        rr_new = jnp.vdot(rc, rc)
        pc = rc + (rr_new / rrc) * pc
        return (xc, rc, pc, rr_new)

    def merged_body(_, c):
        xc, rc, pc, sc, wc, gamma, dlt, gp, ap = c
        alpha, beta = _cg_merged_scalars(gamma, dlt, gp, ap)
        pc = rc + beta * pc
        sc = wc + beta * sc
        xc = xc + alpha * pc
        rc = rc - alpha * sc
        wc = mvp(jnp.pad(rc, 1))
        return (xc, rc, pc, sc, wc, jnp.vdot(rc, rc), jnp.vdot(wc, rc),
                gamma, alpha)

    def pipe_body(_, c):
        xc, rc, wc, pc, sc, zc, gp, ap = c
        gamma, dlt = jnp.vdot(rc, rc), jnp.vdot(wc, rc)
        n = lax.optimization_barrier(mvp(jnp.pad(wc, 1)))
        alpha, beta = _cg_merged_scalars(gamma, dlt, gp, ap)
        zc = n + beta * zc
        sc = wc + beta * sc
        pc = rc + beta * pc
        xc = xc + alpha * pc
        rc = rc - alpha * sc
        wc = wc - alpha * zc
        return (xc, rc, wc, pc, sc, zc, gamma, alpha)

    def bicgstab_merged_body(_, c):
        """The reduction-hiding merged BiCGStab: 2 SpMVs + 9 stacked dot
        partials per iteration, plain jnp inside one jit (the refs ARE the
        single-pass jnp formulation)."""
        yc, rc, wc, pc, sc, zc, tc, vc, alpha, rho = c
        vc, qc, yi, parts = ref.bicgstab_spmv_dots_ref(
            jnp.pad(zc, 1), zc, rc, wc, sc, rhat, tc, alpha, stencil=stencil)
        qy, yy, _qq, rhq, rhy, rht, rhv, rhz, rhs = parts
        omega = qy / yy
        rho_new = rhq - omega * rhy
        beta = (rho_new / rho) * (alpha / omega)
        yc, rc, wc = ref.bicgstab_update1_ref(alpha, omega, yc, pc, qc, yi,
                                              tc, vc)
        tc, pc, sc, zc = ref.bicgstab_spmv_update_ref(
            jnp.pad(wc, 1), wc, rc, pc, sc, zc, vc, omega, beta,
            stencil=stencil)
        rhw = rhy - omega * (rht - alpha * rhv)
        alpha = rho_new / (rhw + beta * (rhs - omega * rhz))
        return (yc, rc, wc, pc, sc, zc, tc, vc, alpha, rho_new)

    def fused_body(_, c):
        xc, rc, pc, sc, wc, gamma, dlt, gp, ap = c
        alpha, beta = _cg_merged_scalars(gamma, dlt, gp, ap)
        if use_pallas:
            xc, rc, pc, sc = ops.cg_body(alpha, beta, xc, rc, pc, sc, wc)
            wc, dlt_new, gamma_new = ops.spmv_dots(jnp.pad(rc, 1), stencil)
        else:
            xc, rc, pc, sc = ref.fused_cg_body_ref(alpha, beta, xc, rc, pc,
                                                   sc, wc)
            wc = mvp(jnp.pad(rc, 1))
            # == stencil_spmv_dots_ref with the centre slice elided (the
            # centre of pad(r) IS r); XLA fuses the dots into the pass
            dlt_new, gamma_new = jnp.vdot(wc, rc), jnp.vdot(rc, rc)
        return (xc, rc, pc, sc, wc, gamma_new, dlt_new, gamma, alpha)

    def fused_pipe_body(_, c):
        xc, rc, wc, pc, sc, zc, gp, ap = c
        if use_pallas:
            # n = A·w plus the (w·r, r·r) pipelined dots, one pass
            n, _nw, dlt, gamma = ops.spmv_dots3(jnp.pad(wc, 1), rc, stencil)
        else:
            n = mvp(jnp.pad(wc, 1))
            gamma, dlt = jnp.vdot(rc, rc), jnp.vdot(wc, rc)
        alpha, beta = _cg_merged_scalars(gamma, dlt, gp, ap)
        if use_pallas:
            xc, rc, wc, pc, sc, zc = ops.pipe_body(alpha, beta, xc, rc, wc,
                                                   pc, sc, zc, n)
        else:
            xc, rc, wc, pc, sc, zc = ref.fused_pipe_body_ref(
                alpha, beta, xc, rc, wc, pc, sc, zc, n)
        return (xc, rc, wc, pc, sc, zc, gamma, alpha)

    def fused_bicgstab_body(_, c):
        yc, rc, wc, pc, sc, zc, tc, vc, alpha, rho = c
        if use_pallas:
            vc, qc, yi, parts = ops.bicgstab_spmv_dots(
                jnp.pad(zc, 1), zc, rc, wc, sc, rhat, tc, alpha, stencil)
        else:
            vc, qc, yi, parts = ref.bicgstab_spmv_dots_ref(
                jnp.pad(zc, 1), zc, rc, wc, sc, rhat, tc, alpha,
                stencil=stencil)
        qy, yy, _qq, rhq, rhy, rht, rhv, rhz, rhs = parts
        omega = qy / yy
        rho_new = rhq - omega * rhy
        beta = (rho_new / rho) * (alpha / omega)
        if use_pallas:
            yc, rc, wc = ops.bicgstab_update1(alpha, omega, yc, pc, qc, yi,
                                              tc, vc)
            tc, pc, sc, zc = ops.bicgstab_spmv_update(
                jnp.pad(wc, 1), wc, rc, pc, sc, zc, vc, omega, beta, stencil)
        else:
            yc, rc, wc = ref.bicgstab_update1_ref(alpha, omega, yc, pc, qc,
                                                  yi, tc, vc)
            tc, pc, sc, zc = ref.bicgstab_spmv_update_ref(
                jnp.pad(wc, 1), wc, rc, pc, sc, zc, vc, omega, beta,
                stencil=stencil)
        rhw = rhy - omega * (rht - alpha * rhv)
        alpha = rho_new / (rhw + beta * (rhs - omega * rhz))
        return (yc, rc, wc, pc, sc, zc, tc, vc, alpha, rho_new)

    bicg_init = (x, r, w, p, s, z, t, v, one, one)
    inits = {
        "cg_classic_jit": ((x, r, p, rr), classic_body, "jit"),
        "cg_merged_jit": ((x, r, p, s, w, rr, delta, inf, one), merged_body,
                          "jit"),
        "cg_pipe_jit": ((x, r, w, p, s, z, inf, one), pipe_body, "jit"),
        "bicgstab_merged_jit": (bicg_init, bicgstab_merged_body, "jit"),
        "fused_iteration": ((x, r, p, s, w, rr, delta, inf, one), fused_body,
                            fused_impl),
        "fused_pipe_iteration": ((x, r, w, p, s, z, inf, one),
                                 fused_pipe_body, fused_impl),
        "fused_bicgstab_iteration": (bicg_init, fused_bicgstab_body,
                                     fused_impl),
    }
    runners = {"cg_classic_kernels": (cg_classic_kernels, "fork-join"),
               "bicgstab_classic_kernels": (bicgstab_classic_kernels,
                                            "fork-join")}
    for name, (init, body, impl) in inits.items():
        loop = jax.jit(lambda init, body=body: lax.fori_loop(
            0, n_iters, body, init))
        runners[name] = ((lambda loop=loop, init=init:
                          jax.block_until_ready(loop(init))), impl)
    return runners


def bench_grid(shape, stencil, *, use_pallas: bool, n_iters: int,
               repeats: int) -> dict:
    state = _state(shape, jnp.float64, n=9)
    rows = {}
    for name, (run, impl) in _runners(stencil, n_iters, state,
                                      use_pallas).items():
        run()                                   # warm-up / compile
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            run()
            ts.append(time.perf_counter() - t0)
        rows[name] = {"per_iter_s": min(ts) / n_iters, "impl": impl}

    # the autotuner row: what a classic solve with pallas="auto" actually
    # executes at this grid.  Below the crossover the decision is the XLA
    # fallback, so the row IS the jitted loop's measurement (deterministic
    # ratio, honest label); above it (TPU) the fused kernel path stands in.
    dec = autotune.resolve(stencil.name, shape, jnp.float64)
    if dec.use_pallas:
        rows["cg_classic_kernels_auto"] = {
            "per_iter_s": rows["fused_iteration"]["per_iter_s"],
            "impl": f"pallas(bz={dec.bz})", "tune_source": dec.source}
    else:
        rows["cg_classic_kernels_auto"] = {
            "per_iter_s": rows["cg_classic_jit"]["per_iter_s"],
            "impl": "xla-fallback(cg_classic_jit)", "tune_source": dec.source}

    gates = {}
    for fused, baseline in FUSED_GATES.items():
        gates[f"{fused}_vs_{baseline}"] = {
            "ratio": rows[baseline]["per_iter_s"] / rows[fused]["per_iter_s"],
            "min": GATE_MIN, "rows": [fused, baseline]}
    gates["auto_vs_cg_classic_jit"] = {
        "ratio": (rows["cg_classic_kernels_auto"]["per_iter_s"]
                  / rows["cg_classic_jit"]["per_iter_s"]),
        "max": AUTO_GATE_MAX,
        "rows": ["cg_classic_kernels_auto", "cg_classic_jit"]}
    return {"rows": rows, "gates": gates}


def check_record(path: str) -> dict:
    """The artifact-level regression gate, run by CI against the freshly
    written smoke record:

    * every per-grid gate must hold (fused >= its fork-join baseline with
      the declared tolerance band; the autotuner row <= the jitted classic
      loop × 1.1) — a refactor that silently slows a fused body fails the
      build even if the bench itself ran;
    * every gated row must carry the implementation that ACTUALLY executed
      — and it must be a measurement: ``pallas-interpret`` (the emulator)
      in a gated row means the comparison silently didn't time the kernel.
    """
    with open(path) as f:
        record = json.load(f)
    bad: list[str] = []
    for key, grid in record["grids"].items():
        for gname, gate in grid["gates"].items():
            for row in gate["rows"]:
                impl = grid["rows"].get(row, {}).get("impl")
                if not impl:
                    bad.append(f"{key}:{row}: gated row has no impl label")
                elif impl == "pallas-interpret":
                    bad.append(
                        f"{key}:{row}: gated row ran the interpret-mode "
                        f"emulator, not the kernel")
            if "min" in gate and gate["ratio"] < gate["min"]:
                bad.append(f"{key}:{gname}: ratio {gate['ratio']:.2f} "
                           f"< {gate['min']}")
            if "max" in gate and gate["ratio"] > gate["max"]:
                bad.append(f"{key}:{gname}: ratio {gate['ratio']:.2f} "
                           f"> {gate['max']}")
    if bad:
        raise SystemExit(f"[bench_kernels] {path}: " + "; ".join(bad))
    ratios = {k: {g: round(gate["ratio"], 2)
                  for g, gate in grid["gates"].items()}
              for k, grid in record["grids"].items()}
    print(f"[bench_kernels] {path}: all gates hold on "
          f"{sorted(record['grids'])} ({ratios})")
    return record


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid + few repeats (the CI regression gate)")
    ap.add_argument("--check", metavar="JSON",
                    help="don't bench: assert an existing BENCH_kernels.json "
                         "still passes every per-grid gate + impl honesty")
    ap.add_argument("--stencil", default="27pt", choices=["7pt", "27pt"])
    ap.add_argument("--iters", type=int, default=None,
                    help="iterations per timed run (amortises dispatch "
                         "noise; default 50, smoke 5)")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--pallas", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="back the fused iterations with the Pallas kernels "
                         "(default: only on a real TPU — interpret mode is "
                         "an emulator, not a measurement, and fails --check)")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_kernels.json"))
    args = ap.parse_args(argv)

    if args.check:
        return check_record(args.check)

    enable_f64()
    use_pallas = (jax.default_backend() == "tpu" if args.pallas is None
                  else args.pallas)
    n_iters = args.iters or (5 if args.smoke else 50)
    repeats = args.repeats or (2 if args.smoke else 5)
    grids = SMOKE_GRIDS if args.smoke else GRIDS
    stencil = STENCILS[args.stencil]

    record = {
        "meta": {
            "backend": jax.default_backend(),
            "fused_impl": _impl_label(use_pallas),
            "dtype": "float64",
            "stencil": args.stencil,
            "iters_per_run": n_iters,
            "repeats": repeats,
            "smoke": bool(args.smoke),
        },
        "grids": {},
    }
    for shape in grids:
        key = "x".join(map(str, shape))
        res = record["grids"][key] = bench_grid(
            shape, stencil, use_pallas=use_pallas, n_iters=n_iters,
            repeats=repeats)
        for name, row in res["rows"].items():
            csv(f"bench_kernels_{key}_{name}", row["per_iter_s"] * 1e6,
                f"per_iter_us={row['per_iter_s'] * 1e6:.1f} "
                f"impl={row['impl']}")
        for gname, gate in res["gates"].items():
            csv(f"bench_kernels_{key}_{gname}", 0.0,
                f"ratio={gate['ratio']:.2f}")
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[bench_kernels] wrote {args.out}")
    # one trajectory-history row per fused body × grid (PR-8 helper)
    hist = os.path.splitext(args.out)[0] + "_history.jsonl"
    for key, grid in record["grids"].items():
        for fused, baseline in FUSED_GATES.items():
            row = grid["rows"][fused]
            trajectory_append(hist, trajectory_row(
                "kernels", smoke=bool(args.smoke), stencil=args.stencil,
                grid=key, kernel=fused, impl=row["impl"],
                per_iter_s=row["per_iter_s"],
                ratio_vs_baseline=grid["gates"]
                [f"{fused}_vs_{baseline}"]["ratio"]))
    print(f"[bench_kernels] appended {hist}")
    # the regression gate: fusion losing to the fork-join kernel baseline
    # means a kernel (or its dispatch structure) regressed — fail loudly.
    # Same criterion as the standalone --check mode, by construction.
    check_record(args.out)
    return record


if __name__ == "__main__":
    main()
