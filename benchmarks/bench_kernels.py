"""Per-iteration microbench of the Krylov iteration bodies (PR 4).

Times N back-to-back iterations of each CG formulation on random state, at
32³ and 64³ (f64, 27-pt — the paper's setting), and writes
``BENCH_kernels.json`` at the repo root (the measured-perf trajectory the
CI bench-smoke step uploads).  Variants:

  * ``cg_classic_kernels`` — the classic iteration as SIX separately
    dispatched kernels (SpMV, p·Ap, x-update, r-update, r·r, p-update),
    driven by a host loop: the fork-join kernel-switch baseline, every
    switch a dispatch + HBM round trip (the paper's §3.3 task-merging
    target).
  * ``cg_classic_jit`` / ``cg_merged_jit`` / ``cg_pipe_jit`` — N
    iterations of the classic / merged / pipelined body inside ONE
    compiled ``fori_loop`` (the regime the actual solvers run in; merged
    and pipelined carry their extra recurrences, single stacked
    reduction).
  * ``fused_iteration``    — the merged iteration via the fused kernels:
    ``fused_cg_body`` + ``spmv_dots`` Pallas passes on TPU (2 VMEM round
    trips per iteration); their single-pass jnp references composed into
    the same loop elsewhere (Pallas ``interpret`` mode is an emulator, not
    a measurement — ``meta.fused_impl`` records which ran).  The
    acceptance bar: beats ``cg_classic_kernels`` at 64³.

Per-iteration time = min over repeats of (N-iteration wall clock)/N — the
min (not median) because this measures the kernels, not container noise.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_kernels            # full
    PYTHONPATH=src python -m benchmarks.bench_kernels --smoke    # CI gate
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax import lax

from benchmarks.common import csv, trajectory_append, trajectory_row
from repro.core.operators import STENCILS
from repro.core.problems import enable_f64
from repro.core.solvers import _cg_merged_scalars
from repro.kernels import ops, ref

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GRIDS = ((32, 32, 32), (64, 64, 64))
SMOKE_GRIDS = ((16, 16, 16),)


def _state(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    return tuple(jax.random.normal(k, shape, dtype) for k in ks)


def _runners(stencil, n_iters: int, state, use_pallas: bool):
    """name -> zero-arg callable running ``n_iters`` iterations, blocked."""
    mvp = stencil.matvec_padded
    x, r, p, s, w, z = state
    one = jnp.asarray(1.0, x.dtype)
    inf = jnp.asarray(jnp.inf, x.dtype)
    rr = jnp.vdot(r, r)
    delta = jnp.vdot(w, r)

    # -- classic CG, six separate kernel dispatches per iteration -------------
    k_spmv = jax.jit(lambda v: mvp(jnp.pad(v, 1)))
    k_dot = jax.jit(jnp.vdot)
    k_axpy = jax.jit(lambda a, v, u: v + a * u)

    def classic_kernels():
        xc, rc, pc, rrc = x, r, p, rr
        for _ in range(n_iters):
            Ap = k_spmv(pc)
            pAp = k_dot(pc, Ap)
            alpha = rrc / pAp
            xc = k_axpy(alpha, xc, pc)
            rc = k_axpy(-alpha, rc, Ap)
            rr_new = k_dot(rc, rc)
            beta = rr_new / rrc
            pc = k_axpy(beta, rc, pc)
            rrc = rr_new
        return jax.block_until_ready((xc, rc, pc, rrc))

    # -- whole-loop compiled variants -----------------------------------------
    def classic_body(_, c):
        xc, rc, pc, rrc = c
        Ap = mvp(jnp.pad(pc, 1))
        alpha = rrc / jnp.vdot(pc, Ap)
        xc = xc + alpha * pc
        rc = rc - alpha * Ap
        rr_new = jnp.vdot(rc, rc)
        pc = rc + (rr_new / rrc) * pc
        return (xc, rc, pc, rr_new)

    def merged_body(_, c):
        xc, rc, pc, sc, wc, gamma, dlt, gp, ap = c
        alpha, beta = _cg_merged_scalars(gamma, dlt, gp, ap)
        pc = rc + beta * pc
        sc = wc + beta * sc
        xc = xc + alpha * pc
        rc = rc - alpha * sc
        wc = mvp(jnp.pad(rc, 1))
        return (xc, rc, pc, sc, wc, jnp.vdot(rc, rc), jnp.vdot(wc, rc),
                gamma, alpha)

    def pipe_body(_, c):
        xc, rc, wc, pc, sc, zc, gp, ap = c
        gamma, dlt = jnp.vdot(rc, rc), jnp.vdot(wc, rc)
        n = lax.optimization_barrier(mvp(jnp.pad(wc, 1)))
        alpha, beta = _cg_merged_scalars(gamma, dlt, gp, ap)
        zc = n + beta * zc
        sc = wc + beta * sc
        pc = rc + beta * pc
        xc = xc + alpha * pc
        rc = rc - alpha * sc
        wc = wc - alpha * zc
        return (xc, rc, wc, pc, sc, zc, gamma, alpha)

    def fused_body(_, c):
        xc, rc, pc, sc, wc, gamma, dlt, gp, ap = c
        alpha, beta = _cg_merged_scalars(gamma, dlt, gp, ap)
        if use_pallas:
            xc, rc, pc, sc = ops.cg_body(alpha, beta, xc, rc, pc, sc, wc)
            wc, dlt_new, gamma_new = ops.spmv_dots(jnp.pad(rc, 1), stencil)
        else:
            xc, rc, pc, sc = ref.fused_cg_body_ref(alpha, beta, xc, rc, pc,
                                                   sc, wc)
            wc = mvp(jnp.pad(rc, 1))
            # == stencil_spmv_dots_ref with the centre slice elided (the
            # centre of pad(r) IS r); XLA fuses the dots into the pass
            dlt_new, gamma_new = jnp.vdot(wc, rc), jnp.vdot(rc, rc)
        return (xc, rc, pc, sc, wc, gamma_new, dlt_new, gamma, alpha)

    inits = {
        "cg_classic_jit": ((x, r, p, rr), classic_body),
        "cg_merged_jit": ((x, r, p, s, w, rr, delta, inf, one), merged_body),
        "cg_pipe_jit": ((x, r, w, p, s, z, inf, one), pipe_body),
        "fused_iteration": ((x, r, p, s, w, rr, delta, inf, one), fused_body),
    }
    runners = {"cg_classic_kernels": classic_kernels}
    for name, (init, body) in inits.items():
        loop = jax.jit(lambda init, body=body: lax.fori_loop(
            0, n_iters, body, init))
        runners[name] = (lambda loop=loop, init=init:
                         jax.block_until_ready(loop(init)))
    return runners


def bench_grid(shape, stencil, *, use_pallas: bool, n_iters: int,
               repeats: int) -> dict:
    state = _state(shape, jnp.float64)
    out = {}
    for name, run in _runners(stencil, n_iters, state, use_pallas).items():
        run()                                   # warm-up / compile
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            run()
            ts.append(time.perf_counter() - t0)
        out[name] = min(ts) / n_iters
    out["fused_vs_classic_kernels"] = (
        out["cg_classic_kernels"] / out["fused_iteration"])
    return out


def check_record(path: str) -> dict:
    """The artifact-level regression gate: assert an existing
    BENCH_kernels.json still reports the fused iteration ≥ the fork-join
    kernel baseline on every grid (exits non-zero otherwise).  CI runs this
    against the freshly-written smoke record so a refactor that silently
    slows the fused path fails the build even if the bench itself ran."""
    with open(path) as f:
        record = json.load(f)
    bad = {k: g["fused_vs_classic_kernels"] for k, g in record["grids"].items()
           if g["fused_vs_classic_kernels"] < 1.0}
    if bad:
        raise SystemExit(
            f"[bench_kernels] {path}: fused iteration slower than the "
            f"fork-join kernel baseline: {bad}")
    print(f"[bench_kernels] {path}: fused >= fork-join baseline on "
          f"{sorted(record['grids'])} "
          f"({ {k: round(g['fused_vs_classic_kernels'], 2) for k, g in record['grids'].items()} })")
    return record


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid + few repeats (the CI regression gate)")
    ap.add_argument("--check", metavar="JSON",
                    help="don't bench: assert an existing BENCH_kernels.json "
                         "still reports fused >= the fork-join baseline")
    ap.add_argument("--stencil", default="27pt", choices=["7pt", "27pt"])
    ap.add_argument("--iters", type=int, default=None,
                    help="iterations per timed run (amortises dispatch "
                         "noise; default 50, smoke 5)")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--pallas", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="back the fused iteration with the Pallas kernels "
                         "(default: only on a real TPU — interpret mode is "
                         "an emulator, not a measurement)")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_kernels.json"))
    args = ap.parse_args(argv)

    if args.check:
        return check_record(args.check)

    enable_f64()
    use_pallas = (jax.default_backend() == "tpu" if args.pallas is None
                  else args.pallas)
    n_iters = args.iters or (5 if args.smoke else 50)
    repeats = args.repeats or (2 if args.smoke else 5)
    grids = SMOKE_GRIDS if args.smoke else GRIDS
    stencil = STENCILS[args.stencil]

    record = {
        "meta": {
            "backend": jax.default_backend(),
            "fused_impl": "pallas" if use_pallas else "jnp-ref single-pass",
            "dtype": "float64",
            "stencil": args.stencil,
            "iters_per_run": n_iters,
            "repeats": repeats,
            "smoke": bool(args.smoke),
        },
        "grids": {},
    }
    for shape in grids:
        key = "x".join(map(str, shape))
        res = record["grids"][key] = bench_grid(
            shape, stencil, use_pallas=use_pallas, n_iters=n_iters,
            repeats=repeats)
        for name, val in res.items():
            if name != "fused_vs_classic_kernels":
                csv(f"bench_kernels_{key}_{name}", val * 1e6,
                    f"per_iter_us={val * 1e6:.1f}")
        csv(f"bench_kernels_{key}_fused_speedup", 0.0,
            f"fused_vs_classic_kernels={res['fused_vs_classic_kernels']:.2f}x")
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[bench_kernels] wrote {args.out}")
    hist = os.path.splitext(args.out)[0] + "_history.jsonl"
    trajectory_append(hist, trajectory_row(
        "kernels", smoke=bool(args.smoke), stencil=args.stencil,
        fused_impl=record["meta"]["fused_impl"],
        grids={k: {"per_iter_s": g["fused_iteration"],
                   "fused_vs_classic_kernels": g["fused_vs_classic_kernels"]}
               for k, g in record["grids"].items()}))
    print(f"[bench_kernels] appended {hist}")
    # the regression gate: fusion losing to the fork-join kernel baseline
    # means a kernel (or its dispatch structure) regressed — fail loudly.
    # Same criterion as the standalone --check mode, by construction.
    check_record(args.out)
    return record


if __name__ == "__main__":
    main()
