"""Shared benchmark utilities: timed runs with box-whisker stats (the paper
reports medians of 10 repetitions), and the TPU v5e hardware model used by
the scaling/roofline projections."""

from __future__ import annotations

import time

import jax
import numpy as np

# TPU v5e constants (per chip) — the dry-run's target hardware
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link
ALLREDUCE_LAT = 5e-6         # base latency per hop-stage (model parameter)


def timed(fn, *args, repeats: int = 10, warmup: int = 1):
    """Median/quartiles of ``repeats`` timed calls (jit'd fn)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts = np.array(ts)
    return {
        "median": float(np.median(ts)),
        "q1": float(np.quantile(ts, 0.25)),
        "q3": float(np.quantile(ts, 0.75)),
        "min": float(ts.min()),
    }


def csv(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
