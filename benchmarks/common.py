"""Shared benchmark utilities: the TPU v5e hardware model used by the
scaling/roofline projections, the repo's CSV line format, and the
benchmark *trajectory* — an append-only JSONL history of runs.

Timing lives in ``repro.api.timing`` (warm-up + ``block_until_ready``; the
paper reports medians of 10 repetitions); the measured benchmarks reach it
through ``SolverSession.timed_solve``.

``BENCH_*.json`` files are overwritten per run (the CI gate checks the
latest record); the trajectory files (``BENCH_*_history.jsonl``) are
*appended* so a regression can be dated: every row carries the git sha,
device kind, dtype and a wall-clock timestamp next to the numbers.
"""

from __future__ import annotations

import json
import subprocess
import time

# TPU v5e constants (per chip) — the dry-run's target hardware
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link
ALLREDUCE_LAT = 5e-6         # base latency per hop-stage (model parameter)


def csv(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def git_sha() -> str | None:
    """The current commit (short sha), or None outside a work tree."""
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=10)
    except OSError:
        return None
    return out.stdout.strip() if out.returncode == 0 else None


def trajectory_row(bench: str, **payload) -> dict:
    """One history row: provenance columns (sha, device kind, dtype,
    timestamp) + the bench's own numbers.  Device/dtype come from jax at
    call time so the row records what actually ran, not what was asked."""
    import jax
    import jax.numpy as jnp

    return {
        "bench": bench,
        "t_wall": time.time(),
        "git_sha": git_sha(),
        "device": jax.devices()[0].device_kind,
        "backend": jax.default_backend(),
        "dtype": str(jnp.zeros(()).dtype),
        **payload,
    }


def trajectory_append(path: str, row: dict) -> None:
    """Append one row to a JSONL trajectory file (never overwrites —
    the point of the history is that old rows survive new runs)."""
    with open(path, "a") as f:
        f.write(json.dumps(row, sort_keys=True) + "\n")
