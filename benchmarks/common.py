"""Shared benchmark utilities: the TPU v5e hardware model used by the
scaling/roofline projections, and the repo's CSV line format.

Timing lives in ``repro.api.timing`` (warm-up + ``block_until_ready``; the
paper reports medians of 10 repetitions); the measured benchmarks reach it
through ``SolverSession.timed_solve``.
"""

from __future__ import annotations

# TPU v5e constants (per chip) — the dry-run's target hardware
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link
ALLREDUCE_LAT = 5e-6         # base latency per hop-stage (model parameter)


def csv(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
