"""Serving benchmark: replay the fixed heterogeneous trace through
``repro.serve`` and write ``BENCH_serve.json``.

The workload is ``repro.serve.trace.MIXED_BUCKETS`` (two grids x two
methods, one preconditioned — four executables) streamed through the
service's continuous batcher.  The record carries the SLO numbers a
capacity plan needs — sustained QPS, p50/p95/p99 end-to-end latency,
per-bucket compile seconds — plus the integrity facts the CI gate
asserts:

  * ``dropped == 0``      — every admitted request completed;
  * ``compiles_per_bucket == 1`` — each bucket compiled exactly once
    (``SolverSession.cache_stats()``), i.e. the padded-batch executable
    cache actually amortises compilation across the stream;
  * ``qps >= qps_floor`` and ``p99_s <= p99_ceiling_s`` — the smoke
    SLO gate on the fixed CPU trace (loose bounds: CI containers are
    noisy; a 10x regression still fails loudly).

``--chaos`` replays the smoke workload under injected faults (one
preemption absorbed by the in-place retry, one bucket whose compile
fails and must turn into typed rejects) and writes a SEPARATE record,
``BENCH_serve_chaos.json``, with its own gate (``check_chaos_record``):
every request accounted for (completed + rejected == submitted), the
broken bucket fully rejected with reason ``compile_failed``, and the
preemption retried rather than requeued.  The clean-run record and its
``compiles_per_bucket == 1`` invariant are never polluted by chaos.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_serve            # full
    PYTHONPATH=src python -m benchmarks.bench_serve --smoke    # CI gate
    PYTHONPATH=src python -m benchmarks.bench_serve --chaos    # chaos gate
    PYTHONPATH=src python -m benchmarks.bench_serve --check BENCH_serve.json
"""

from __future__ import annotations

import argparse
import json
import os

import jax

from benchmarks.common import csv, trajectory_append, trajectory_row
from repro.core.problems import enable_f64
# SMOKE_BUCKETS lives with the trace definitions since PR 8 (launch/serve.py
# and make obs-smoke replay the same workload); re-exported here for
# back-compat with callers that imported it from the bench
from repro.serve import (SMOKE_BUCKETS, ServeConfig, SolverService,
                         generate_trace, replay)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: smoke-gate SLO bounds on the fixed CPU trace (generous: a CI container
#: is noisy; these catch order-of-magnitude regressions, not jitter)
SMOKE_QPS_FLOOR = 0.5
SMOKE_P99_CEILING_S = 60.0


def check_record(path: str) -> dict:
    """The artifact-level gate: assert an existing BENCH_serve.json still
    reports zero drops, one compile per bucket, and SLOs within the
    bounds recorded alongside the measurements."""
    with open(path) as f:
        record = json.load(f)
    meta, m = record["meta"], record["metrics"]
    problems = []
    if record["dropped"] != 0:
        problems.append(f"dropped {record['dropped']} request(s)")
    bad_compiles = {b: n for b, n in record["compiles_per_bucket"].items()
                    if n != 1}
    if bad_compiles:
        problems.append(f"compiles per bucket != 1: {bad_compiles}")
    if m["qps"] < meta["qps_floor"]:
        problems.append(f"qps {m['qps']:.2f} < floor {meta['qps_floor']}")
    if m["p99_s"] > meta["p99_ceiling_s"]:
        problems.append(
            f"p99 {m['p99_s']:.2f}s > ceiling {meta['p99_ceiling_s']}s")
    if problems:
        raise SystemExit(f"[bench_serve] {path}: " + "; ".join(problems))
    print(f"[bench_serve] {path}: {record['completed']} requests over "
          f"{len(record['compiles_per_bucket'])} buckets, 0 dropped, "
          f"1 compile/bucket, qps={m['qps']:.2f} (floor "
          f"{meta['qps_floor']}), p99={m['p99_s']:.2f}s (ceiling "
          f"{meta['p99_ceiling_s']}s)")
    return record


def check_chaos_record(path: str) -> dict:
    """The chaos-run gate: every submitted request accounted for, the
    injected compile failure converted to typed rejects (exactly the
    broken bucket's traffic), and the injected preemption absorbed by the
    in-place retry instead of a WAL requeue."""
    with open(path) as f:
        record = json.load(f)
    m = record["metrics"]
    problems = []
    if record["completed"] + record["rejected"] != record["requests"]:
        problems.append(
            f"{record['requests']} submitted but only "
            f"{record['completed']} completed + {record['rejected']} "
            f"rejected — requests stranded")
    want_cf = record["meta"]["expect_compile_fail_rejects"]
    got_cf = record["rejects_by_reason"].get("compile_failed", 0)
    if got_cf != want_cf:
        problems.append(f"compile_failed rejects {got_cf} != "
                        f"expected {want_cf} (the broken bucket's traffic)")
    if m["retries"] < 1:
        problems.append("injected preemption never hit the retry path")
    if m["preemptions"] != 0:
        problems.append(f"{m['preemptions']} preemption(s) fell through "
                        f"to the WAL requeue despite the retry budget")
    if problems:
        raise SystemExit(f"[bench_serve --chaos] {path}: "
                         + "; ".join(problems))
    print(f"[bench_serve --chaos] {path}: {record['requests']} requests -> "
          f"{record['completed']} completed, {record['rejected']} typed "
          f"rejects ({record['rejects_by_reason']}), "
          f"{m['retries']} retry(ies), 0 stranded")
    return record


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grids + small counts (the CI gate)")
    ap.add_argument("--chaos", action="store_true",
                    help="smoke workload under injected faults (one "
                         "preemption + one compile failure); writes "
                         "BENCH_serve_chaos.json with its own gate")
    ap.add_argument("--check-chaos", metavar="JSON",
                    help="assert an existing BENCH_serve_chaos.json still "
                         "meets the chaos gate")
    ap.add_argument("--check", metavar="JSON",
                    help="don't bench: assert an existing BENCH_serve.json "
                         "still meets its recorded SLO bounds")
    ap.add_argument("--scale", type=int, default=None,
                    help="trace size multiplier per bucket (default: "
                         "smoke 1, full 4)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-capacity", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--qps-floor", type=float, default=None)
    ap.add_argument("--p99-ceiling", type=float, default=None)
    ap.add_argument("--out", default=None,
                    help="record path (default BENCH_serve.json, or "
                         "BENCH_serve_chaos.json under --chaos)")
    args = ap.parse_args(argv)
    args.out = args.out or os.path.join(
        ROOT, "BENCH_serve_chaos.json" if args.chaos else "BENCH_serve.json")

    if args.check:
        return check_record(args.check)
    if args.check_chaos:
        return check_chaos_record(args.check_chaos)

    enable_f64()
    smoke = args.smoke or args.chaos
    buckets = SMOKE_BUCKETS if smoke else None
    scale = args.scale or (1 if smoke else 4)
    trace = (generate_trace(buckets, seed=args.seed, scale=scale)
             if buckets else generate_trace(seed=args.seed, scale=scale))
    injector = None
    expect_cf = 0
    if args.chaos:
        from repro.resilience import ChaosInjector, ChaosPlan
        # one bucket that will never compile + one preemption the retry
        # budget must absorb; both seeded, so the record is reproducible
        broken = "bicgstab_b1"
        expect_cf = sum(1 for r in trace if r.method == broken)
        injector = ChaosInjector(ChaosPlan(
            seed=args.seed, fail_compile_buckets=(broken,),
            preempt_at=(0,)))
        cfg = ServeConfig(max_batch=args.max_batch,
                          cache_capacity=args.cache_capacity,
                          guards=True, max_retries=2,
                          retry_backoff_s=0.01, retry_seed=args.seed)
    else:
        cfg = ServeConfig(max_batch=args.max_batch,
                          cache_capacity=args.cache_capacity)
    service = SolverService(cfg, injector=injector)
    results = replay(service, trace)
    service.close()
    snap = service.snapshot()

    compiles = {b: st["misses"]
                for b, st in snap["cache"]["per_bucket"].items()}
    record = {
        "meta": {
            "backend": jax.default_backend(),
            "smoke": bool(smoke),
            "chaos": bool(args.chaos),
            "expect_compile_fail_rejects": expect_cf,
            "seed": args.seed,
            "scale": scale,
            "max_batch": cfg.max_batch,
            "cache_capacity": cfg.cache_capacity,
            "qps_floor": args.qps_floor or SMOKE_QPS_FLOOR,
            "p99_ceiling_s": args.p99_ceiling or SMOKE_P99_CEILING_S,
        },
        "requests": len(trace),
        "completed": len(results),
        "rejected": len(service.rejects()),
        "rejects_by_reason": snap["rejects_by_reason"],
        "dropped": len(trace) - len(results) - len(service.rejects()),
        "compiles_per_bucket": compiles,
        "compile_s_per_bucket": {
            b: st["compile_s"]
            for b, st in snap["cache"]["per_bucket"].items()},
        "metrics": {k: snap[k] for k in
                    ("qps", "p50_s", "p95_s", "p99_s", "queue_depth_max",
                     "preemptions", "requeued", "retries", "device_losses",
                     "completed")},
        "per_bucket": snap["per_bucket"],
    }
    for b, st in snap["per_bucket"].items():
        csv(f"bench_serve_{b}_p50", st["p50_s"] * 1e6,
            f"served={st['served']} p99_ms={st['p99_s']*1e3:.1f}")
    csv("bench_serve_qps", 0.0, f"qps={snap['qps']:.2f} "
        f"p99_ms={snap['p99_s']*1e3:.1f}")
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[bench_serve] wrote {args.out}")
    hist = os.path.splitext(args.out)[0] + "_history.jsonl"
    trajectory_append(hist, trajectory_row(
        "serve", smoke=bool(smoke), chaos=bool(args.chaos), scale=scale,
        requests=len(trace), completed=len(results),
        qps=snap["qps"], p50_s=snap["p50_s"], p99_s=snap["p99_s"]))
    print(f"[bench_serve] appended {hist}")
    # same criterion as the standalone --check gates, by construction
    if args.chaos:
        check_chaos_record(args.out)
    else:
        check_record(args.out)
    return record


if __name__ == "__main__":
    main()
