"""Figs. 5-6: strong scalability, fixed 128x128x6144 grid.

Reproduces the paper's qualitative findings in the TPU model: per-chip work
shrinks with n while collective latency does not, so every method's
efficiency decays; methods with fewer/hidden blocking reductions decay
slower; past the point where the block fits on-chip cache/VMEM the advantage
vanishes (the paper's data-locality crossover).
"""

from __future__ import annotations

from benchmarks.common import csv
from benchmarks.scaling_model import strong_efficiency
from repro.api import solver_names

CHIPS = (1, 8, 48, 96, 192, 384, 768, 1536, 3072, 6144)


def main() -> None:
    # every registered method with a scaling-model entry (rb-GS shares the
    # relaxed-GS curve, so only the relaxed variant is plotted)
    methods = [m for m in solver_names() if m != "gauss_seidel_rb"]
    for noise in ("tpu", "noisy"):
        for stencil, nbar in (("7pt", 7), ("27pt", 27)):
            for method in methods:
                effs = [round(strong_efficiency(method, nbar, n, noise=noise,
                                                halo_mode="overlap"), 4)
                        for n in CHIPS]
                csv(f"fig56_{noise}_{stencil}_{method}", 0.0,
                    "eff@" + "/".join(map(str, CHIPS)) + "="
                    + "/".join(map(str, effs)))
            # crossover: first n losing >half the single-chip efficiency
            for method in ("cg", "cg_nb"):
                cross = next((n for n in CHIPS if strong_efficiency(
                    method, nbar, n, noise=noise,
                    halo_mode="overlap") < 0.5), None)
                csv(f"fig56_{noise}_{stencil}_{method}_half_eff_at", 0.0,
                    str(cross))


if __name__ == "__main__":
    main()
