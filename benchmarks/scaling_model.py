"""Per-iteration TPU time model for the solver scaling figures.

The paper measures wall-clock on MareNostrum4; this repo targets TPU v5e and
derives the same *relative efficiency* curves from the roofline terms (the
container is CPU-only — DESIGN.md §7).  Model per iteration and device:

  T = T_mem + T_halo + T_precond + Σ_r max(0, Λ(n) - hide_r)

  * T_mem   — the method's touched-elements traffic / HBM bandwidth (the
              paper's own §3.1 memory model; solvers are memory-bound),
  * T_halo  — nearest-neighbour face exchange per SpMV over ICI; with
              ``halo_mode="overlap"`` each registry-marked SpMV's exchange
              hides behind its interior apply and only the excess
              max(0, t_halo - t_spmv) stays on the critical path,
  * T_precond — the preconditioner applies' traffic + any halo exchanges
              they perform (from the repro.precond metadata: applies/iter
              come from the registry, per-apply touched elements and halo
              matvecs from the Preconditioner instance; block-Jacobi is
              communication-free, SSOR's half-sweep exchanges cannot hide).
              No reduction term: the built-ins add zero reductions — that
              is the subsystem's design constraint,
  * Λ(n)    — all-reduce latency, λ·ceil(log2 chips)·(1+noise·log2 chips):
              the noise term models the system-noise amplification the paper
              measures (Allreduce 1e-5 s in isolation vs 1e-3 s in
              application context, §4.2),
  * hide_r  — the overlap window of reduction r (0 for blocking reductions;
              the SpMV or vector-update time for reductions the variant
              overlaps, per §3.1's own overlap condition; the SpMV + M-apply
              for the "pipe" kind — the pipelined variants' single stacked
              reduction rides behind the body's SpMV, see ``t_reduce``).

The merged variants (cg_merged & co., reduce_hide="merged") pay Λ(n) ONCE
per iteration instead of 2–3 times; the pipelined ones (cg_pipe/pcg_pipe)
additionally hide that one payment behind the SpMV — their curves in
fig3/fig56 are flat in Λ until Λ(n) exceeds a whole SpMV.

Validated against the dry-run solver cells at 256/512 chips (roofline.py
cross-checks hlo_bytes against this T_mem within the f32-legalisation factor).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from benchmarks.common import ALLREDUCE_LAT, HBM_BW, ICI_BW
from repro.api.registry import REGISTRY
from repro.core.operators import touched_elements_per_iter

# Noise regimes: per-log2-stage amplification of collective latency.
#   "tpu"   — synchronous SPMD fabric, negligible OS jitter (ICI),
#   "noisy" — the paper's MPI-cluster regime: calibrated so a 3072-rank
#             all-reduce costs ~1.1 ms, matching §4.2's measured 1e-3 s
#             ("up to two orders of magnitude larger than the minimum
#             latency" of 1e-5 s).
NOISE = {"tpu": 0.03, "noisy": 1.5}


@dataclass(frozen=True)
class MethodModel:
    name: str
    n_spmv: int               # SpMVs per iteration
    reductions: tuple         # per reduction: hide window kind
    # hide kinds: "none" (blocking), "spmv", "vec" (one vector update)
    halo_hides: tuple = ()    # per SpMV: "interior" (overlappable) | "none"
    precond_applies: int = 0  # M^{-1} applications per iteration
    refresh_spmvs: int = 0    # SpMV-equivalents per residual replacement


#: derived from the solver registry — the per-iteration communication
#: structure is method metadata, declared once in repro.api.registry.
METHODS = {
    name: MethodModel(name, spec.spmvs_per_iter,
                      tuple((h,) for h in spec.reduction_hides),
                      spec.halo_hides, spec.precond_applies_per_iter,
                      getattr(spec.method_def, "refresh_spmvs", 0))
    for name, spec in REGISTRY.items()
}


def iteration_breakdown(method: str, nbar: int,
                        local_grid: tuple[int, int, int],
                        chips: int, *, dtype_bytes: int = 8,
                        decomposition: str = "1d", noise: str = "tpu",
                        execution: str = "dataflow",
                        halo_mode: str = "concat",
                        precond: str | None = None,
                        precond_params: dict | None = None,
                        refresh_every: int = 0) -> dict:
    """``execution``: "mpi" = every reduction blocks (the paper's MPI-only
    baseline); "dataflow" = reductions hide behind their overlap windows
    (what the task runtime buys in the paper / XLA buys here).

    ``halo_mode="overlap"`` additionally hides each SpMV's halo exchange
    behind its interior apply (the interior/shell split of
    ``DistributedOp._matvec_overlap``) for the SpMVs the registry marks
    ``halo_hides="interior"`` — the Gauss-Seidel sweeps read their halos at
    the first plane/colour and stay exposed.  Under ``execution="mpi"``
    halos block regardless (the paper's fork-join exchange_externals).

    ``precond`` adds the t_precond term for the methods that apply one
    (``REGISTRY[...].precond_applies_per_iter``): per apply, the
    preconditioner's touched-elements traffic plus its halo exchanges
    (hidden like a regular SpMV's when the instance marks them
    ``halo_hide="interior"`` and overlap is on).  This prices ONE
    iteration; the payoff — fewer iterations — is the other axis of the
    trade-off (see benchmarks/table_iterations.py for measured counts).

    ``refresh_every`` prices residual replacement (repro.resilience: the
    merged/pipelined drift mitigation, ``SolverOptions.residual_replacement``)
    as an amortised per-iteration term ``t_rr``: every N-th iteration pays
    the method's ``refresh_spmvs`` SpMV-equivalents (memory + halo, never
    hidden — the refresh sits on the critical path by construction) plus
    one blocking stacked reduction to re-derive the recurrence scalars.
    0 (the default) or a method with no refresh hook prices as 0.

    Returns the per-phase split ``{"t_mem", "t_halo", "t_precond",
    "t_reduce", "t_rr", "total"}`` — the prediction
    ``repro.obs.attribution`` lines up against measured phase times;
    :func:`iteration_time` is its ``total``.
    """
    r = local_grid[0] * local_grid[1] * local_grid[2]
    m = METHODS[method]
    touched = touched_elements_per_iter(method, nbar)
    t_mem = touched * r * dtype_bytes / HBM_BW
    t_vec = 3 * r * dtype_bytes / HBM_BW          # one z = ax+by update
    t_spmv = (nbar + 2) * r * dtype_bytes / HBM_BW
    # halo: 1-D decomposition exchanges 2 faces per SpMV
    if decomposition == "1d":
        face = local_grid[0] * local_grid[1] * dtype_bytes
        t_halo_spmv = 2 * face / ICI_BW if chips > 1 else 0.0
    else:  # 3-D blocks: surface scales with block^(2/3)
        face = (r ** (2 / 3)) * dtype_bytes
        t_halo_spmv = 6 * face / ICI_BW if chips > 1 else 0.0
    t_halo = 0.0
    for halo_hide in m.halo_hides:
        if (halo_mode == "overlap" and execution == "dataflow"
                and halo_hide == "interior"):
            # the interior apply (~the whole SpMV's HBM traffic) runs while
            # the ppermutes fly; only the excess stays on the critical path
            t_halo += max(0.0, t_halo_spmv - t_spmv)
        else:
            t_halo += t_halo_spmv
    # preconditioner applies (pcg family: 1, pbicgstab family: 2, else 0)
    t_pre_apply = 0.0
    if precond not in (None, "none") and m.precond_applies:
        from repro.precond import make_precond
        inst = make_precond(precond, **(precond_params or {}))
        t_pre_apply = (inst.touched_elements_per_apply(nbar) * r * dtype_bytes
                       / HBM_BW)
        for _ in range(inst.halo_matvecs_per_apply):
            if (halo_mode == "overlap" and execution == "dataflow"
                    and inst.halo_hide == "interior"):
                t_pre_apply += max(0.0, t_halo_spmv - t_spmv)
            else:
                t_pre_apply += t_halo_spmv
    t_pre = t_pre_apply * m.precond_applies
    # reductions — the t_reduce hide term: per reduction, the all-reduce
    # latency Λ(n) minus the variant's overlap window.  "pipe" is the
    # Ghysels–Vanroose window: the pipelined stacked psum rides behind the
    # body's SpMV plus (for pcg_pipe) the preconditioner apply it also
    # overlaps — structurally the same trick halo_mode="overlap" plays for
    # the ppermutes, applied to the global reduction.
    t_red = t_reduce(m, chips, noise=noise, execution=execution,
                     t_vec=t_vec, t_spmv=t_spmv, t_pre_apply=t_pre_apply)
    # residual replacement, amortised over its period: refresh_spmvs
    # un-hidden SpMVs + one blocking stacked reduction every N iterations
    t_rr = 0.0
    if refresh_every > 0 and m.refresh_spmvs:
        t_rr = (m.refresh_spmvs * (t_spmv + t_halo_spmv)
                + reduction_latency(chips, noise=noise)) / refresh_every
    return {"t_mem": t_mem, "t_halo": t_halo, "t_precond": t_pre,
            "t_reduce": t_red, "t_rr": t_rr,
            "total": t_mem + t_halo + t_pre + t_red + t_rr}


def iteration_time(method: str, nbar: int, local_grid: tuple[int, int, int],
                   chips: int, **kw) -> float:
    """Total modelled per-iteration time — ``iteration_breakdown(...)``
    summed (see that function for the knobs and the model)."""
    return iteration_breakdown(method, nbar, local_grid, chips, **kw)["total"]


def reduction_latency(chips: int, *, noise: str = "tpu") -> float:
    """Λ(n): modelled all-reduce latency at ``chips`` devices."""
    if chips <= 1:
        return 0.0
    stages = math.ceil(math.log2(chips))
    return ALLREDUCE_LAT * stages * (1 + NOISE[noise] * stages)


def t_reduce(m: MethodModel, chips: int, *, noise: str, execution: str,
             t_vec: float, t_spmv: float, t_pre_apply: float = 0.0) -> float:
    """The per-iteration reduction term: Σ_r max(0, Λ(n) − hide_r).

    Hide windows per kind: "none" 0, "vec" one vector update, "spmv" the
    SpMV, "pipe" the SpMV + preconditioner apply the pipelined stacked
    reduction overlaps.  Under ``execution="mpi"`` every reduction blocks
    (the paper's fork-join baseline).
    """
    if chips <= 1:
        return 0.0
    lat = reduction_latency(chips, noise=noise)
    total = 0.0
    for (kind,) in m.reductions:
        if execution == "mpi":
            hide = 0.0
        else:
            hide = {"none": 0.0, "vec": t_vec, "spmv": t_spmv,
                    "pipe": t_spmv + t_pre_apply}[kind]
        total += max(0.0, lat - hide)
    return total


def weak_efficiency(method: str, nbar: int, chips: int,
                    local=(128, 128, 128), **kw) -> float:
    """T(1)/T(n) at constant per-chip work (the paper's Fig. 3/4 metric)."""
    t1 = iteration_time(method, nbar, local, 1, **kw)
    tn = iteration_time(method, nbar, local, chips, **kw)
    return t1 / tn


def strong_efficiency(method: str, nbar: int, chips: int,
                      global_grid=(128, 128, 6144), **kw) -> float:
    t1 = iteration_time(method, nbar, global_grid, 1, **kw)
    local = (global_grid[0], global_grid[1], max(global_grid[2] // chips, 1))
    tn = iteration_time(method, nbar, local, chips, **kw)
    return t1 / (chips * tn)
