"""Fig. 4: weak scalability of Jacobi + symmetric Gauss-Seidel, including the
GS-variant iteration-count effect the paper measures in Fig. 4(d)
(MPI 157 vs bicoloured 166 vs relaxed 150 at the 27pt stencil).

Part 1: efficiency curves from the iteration-time model.
Part 2: measured iteration counts of the GS variants on CPU (the convergence
        differences are real algorithm properties, not hardware ones).
"""

from __future__ import annotations

from benchmarks.common import csv
from benchmarks.scaling_model import iteration_time
from repro.api import SolverOptions, SolverSession
from repro.core.problems import enable_f64

CHIPS = (1, 8, 64, 256, 512, 1024, 4096)


def main() -> None:
    enable_f64()      # paper precision; owned by the driver, not the facade
    for noise in ("tpu", "noisy"):
        for stencil, nbar in (("7pt", 7), ("27pt", 27)):
            for method, ex in (("jacobi", "mpi"), ("jacobi", "dataflow"),
                               ("gauss_seidel", "mpi"),
                               ("gauss_seidel", "dataflow")):
                t_ref = iteration_time(method, nbar, (128, 128, 128), 1,
                                       noise=noise, execution="mpi")
                halo = "overlap" if ex == "dataflow" else "concat"
                effs = [round(t_ref / iteration_time(
                    method, nbar, (128, 128, 128), n, noise=noise,
                    execution=ex, halo_mode=halo), 4) for n in CHIPS]
                csv(f"fig4_{noise}_{stencil}_{method}_{ex}", 0.0,
                    "eff@" + "/".join(map(str, CHIPS)) + "="
                    + "/".join(map(str, effs)))

    # GS variant convergence (measured)
    counts = {}
    for variant in ("gauss_seidel", "gauss_seidel_rb"):
        res = SolverSession(
            method=variant, grid=(48, 48, 48), stencil="27pt",
            options=SolverOptions(tol=1e-6, maxiter=1500,
                                  layout="local")).solve()
        counts[variant] = int(res.iters)
        csv(f"fig4d_iters_{variant}", 0.0, f"iters={int(res.iters)}")
    csv("fig4d_variant_ratio", 0.0,
        f"relaxed/rb={counts['gauss_seidel']/counts['gauss_seidel_rb']:.3f}"
        f" (paper: 150/166={150/166:.3f})")


if __name__ == "__main__":
    main()
